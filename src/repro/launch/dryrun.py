import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``.lower().compile()`` must succeed on the 16×16 single-pod mesh AND the
    2×16×16 multi-pod mesh for every runnable cell;
  * ``memory_analysis()`` proves the per-device working set fits;
  * ``cost_analysis()`` + the HLO walker (hlo_cost.py) yield the roofline
    terms (single-pod only — §Roofline in EXPERIMENTS.md).

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun.jsonl
    python -m repro.launch.dryrun --all --mesh multi   # compile-proof only
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

# TPU v5e hardware model (targets; this container compiles on CPU)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        return {}


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) or 2·N_active·B (decode),
    per executed step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.encoder_layers > 0:
            from repro.configs.shapes import WHISPER_DECODER_LEN
            tokens = shape.global_batch * (shape.seq_len + WHISPER_DECODER_LEN)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    from repro.configs import registry, shapes
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_production_mesh
    from repro.launch import steps

    cfg = registry.get_config(arch)
    spec = shapes.SHAPES[shape_name]
    runnable, reason = shapes.cell_is_runnable(cfg, spec)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "kind": spec.kind}
    if not runnable:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    if spec.kind == "train":
        lowered, compiled = steps.compile_train(cfg, mesh, spec)
    elif spec.kind == "prefill":
        lowered, compiled = steps.compile_prefill(cfg, mesh, spec)
    else:
        lowered, compiled = steps.compile_serve_step(cfg, mesh, spec)
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["memory"] = _mem_dict(compiled)

    ca = compiled.cost_analysis() or {}
    rec["xla_cost"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                       if k in ca}

    rep = hlo_cost.analyze(compiled.as_text())
    rec["hlo"] = {
        "flops_per_device": rep.flops,
        "bytes_per_device": rep.bytes,
        "collective_bytes_per_device": rep.collective_bytes,
        "collectives": dict(rep.collectives),
        "collective_counts": {k: int(v) for k, v in rep.collective_counts.items()},
        "unknown_trip_whiles": rep.unknown_trip_whiles,
    }
    # roofline terms, per-device quantities over per-chip rates
    compute_s = rep.flops / PEAK_FLOPS
    memory_s = rep.bytes / HBM_BW
    collective_s = rep.collective_bytes / ICI_BW
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (collective_s, "collective"))[1]
    mf = model_flops(cfg, spec)
    total_hlo_flops = rep.flops * chips
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": total_hlo_flops,
        "useful_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "bound_s": max(compute_s, memory_s, collective_s),
        "ideal_compute_s": mf / (chips * PEAK_FLOPS),
    }
    rec["roofline"]["roofline_fraction"] = (
        rec["roofline"]["ideal_compute_s"] / rec["roofline"]["bound_s"]
        if rec["roofline"]["bound_s"] else 0.0)
    rec["status"] = "ok"
    if verbose:
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status", "compile_s")}))
        print("  memory:", rec["memory"])
        print("  roofline:", {k: (f"{v:.4g}" if isinstance(v, float) else v)
                              for k, v in rec["roofline"].items()})
    return rec


def main():
    from repro.configs import registry, shapes

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    cells = []
    archs = sorted(registry.ARCHS) if (args.all or not args.arch) else [args.arch]
    shape_names = list(shapes.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shape_names:
            for m in meshes:
                cells.append((a, s, m))

    failures = 0
    for a, s, m in cells:
        try:
            rec = run_cell(a, s, m)
        except Exception as e:  # a failed cell is a bug in the system
            rec = {"arch": a, "shape": s, "mesh": "2x16x16" if m else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
            print(f"FAILED {a} × {s} ({rec['mesh']}): {rec['error']}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
