"""Training driver: checkpointed, preemption-safe, straggler-monitored.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); the full configs are
for real accelerators. The loop demonstrates the whole fault-tolerance
surface: resume-from-latest, SIGTERM checkpointing, per-step straggler
detection, deterministic data (restarts are bit-exact).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_loop(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
               ckpt_dir: str | None, ckpt_every: int = 50, microbatches: int = 1,
               resume: bool = True, seed: int = 0, log_every: int = 10,
               fail_at_step: int | None = None):
    from repro.configs import registry
    from repro.data.pipeline import lm_batch
    from repro.models import transformer as T
    from repro.train import checkpoint as CK
    from repro.train import fault as F
    from repro.train import train_step as TS

    cfg = registry.get_smoke_config(arch) if smoke else registry.get_config(arch)
    tcfg = TS.TrainConfig(microbatches=microbatches)
    step_fn = jax.jit(TS.make_train_step(cfg, tcfg), donate_argnums=(0,))

    start = 0
    state = None
    if ckpt_dir and resume:
        latest = CK.latest_step(ckpt_dir)
        if latest is not None:
            abs_state = TS.abstract_state(cfg)
            state = CK.restore(ckpt_dir, latest, abs_state)
            start = latest
            print(f"resumed from step {latest}")
    if state is None:
        state = TS.init_state(cfg, jax.random.PRNGKey(seed))

    monitor = F.StragglerMonitor()
    preempt = F.PreemptionHandler()
    losses = []
    for step in range(start, steps):
        bd = lm_batch(cfg, batch, seq, seed=seed, step=step, microbatches=microbatches)
        bd = {k: jnp.asarray(v) for k, v in bd.items()}
        t0 = time.time()
        state, metrics = step_fn(state, bd)
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.record(step, time.time() - t0)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} dt {time.time()-t0:.2f}s")
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or step == steps - 1
                         or preempt.should_checkpoint):
            CK.save(ckpt_dir, step + 1, state)
            if preempt.should_checkpoint:
                print("preemption requested — checkpointed and exiting")
                break
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (tests the supervisor restart path)")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    from repro.train import checkpoint as CK
    from repro.train import fault as F

    def make_loop(resume_step):
        state, losses = train_loop(
            args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
            seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            microbatches=args.microbatches, seed=args.seed,
            fail_at_step=args.fail_at_step if (resume_step or 0) == 0 else None)
        return args.steps

    if args.ckpt_dir and args.fail_at_step is not None:
        F.run_with_restart(make_loop, lambda: CK.latest_step(args.ckpt_dir),
                           max_restarts=args.max_restarts, backoff_s=0.1)
    else:
        make_loop(None)


if __name__ == "__main__":
    main()
