"""Join-correlation query serving driver (the paper's end-to-end system).

Builds a sketch index over a synthetic table collection, shards it over all
available devices, and serves batched top-k join-correlation queries,
reporting the latency percentiles of §5.5.

    PYTHONPATH=src python -m repro.launch.serve --tables 2000 --queries 200 \
        --sketch-size 256 --k 10
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", type=int, default=1000)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--sketch-size", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--estimator", default="pearson", choices=("pearson", "spearman"))
    ap.add_argument("--scorer", default="s4", choices=("s1", "s2", "s4"))
    ap.add_argument("--rows-max", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0,
                    help="serve through the batched engine with this request "
                         "batch size (0 = sequential single-query loop)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.core import build_sketch
    from repro.data.pipeline import Table, sbn_pair, skewed_pair
    from repro.engine import index as IX
    from repro.engine import serve as SV
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(args.seed)
    print(f"generating {args.tables} tables ...")
    tables = []
    queries = []
    for i in range(args.tables):
        gen = sbn_pair if i % 2 == 0 else skewed_pair
        tx, ty, r, c = gen(rng, n_max=args.rows_max)
        tables.append(Table(keys=ty.keys, values=ty.values, name=f"t{i}"))
        if len(queries) < args.queries:
            queries.append(Table(keys=tx.keys, values=tx.values, name=f"q{i}", meta={"r": r}))

    mesh = make_host_mesh()
    ndev = mesh.devices.size
    pad = ((args.tables + ndev - 1) // ndev) * ndev
    t0 = time.time()
    idx = IX.build_index(tables, n=args.sketch_size, pad_to=pad)
    build_s = time.time() - t0
    print(f"index built: {args.tables} columns, sketch n={args.sketch_size}, "
          f"{build_s:.1f}s ({args.tables/build_s:.0f} cols/s)")
    shard = IX.shard_for_mesh(idx, mesh)

    from repro.engine import plans as PL
    shape = PL.ShapePolicy(k_max=args.k)
    req = PL.Request(k=args.k, estimator=args.estimator, scorer=args.scorer)

    if args.batch > 0:
        # only buckets the request loop can actually select (≤ args.batch)
        buckets = tuple(b for b in (1, 8, 32) if b < args.batch) + (args.batch,)
        srv = SV.Server(mesh, idx, shape, request=req, buckets=buckets)
        srv.warmup(modes=("off",))
        qsks = SV.build_query_sketches([q.keys for q in queries],
                                       [q.values for q in queries],
                                       n=args.sketch_size)
        for s in range(0, len(queries), args.batch):
            batch = jax.tree.map(lambda a, s=s: a[s:s + args.batch], qsks)
            srv.query_batch(batch)
        st = srv.throughput()
        print(f"batched serving (B≤{args.batch}): {st['queries']} queries in "
              f"{st['dispatches']} dispatches — per-query {st['per_query_ms']:.2f} ms, "
              f"{st['qps']:.0f} queries/sec, dispatch p50 {st['dispatch_p50_ms']:.1f} ms "
              f"p99 {st['dispatch_p99_ms']:.1f} ms")
        return

    qfn = PL.make_scan_fn(mesh, shard.num_columns, args.sketch_size, shape)
    ops = jnp.asarray(PL.request_operands(req))

    lat = []
    for i, qt in enumerate(queries):
        qsk = build_sketch(jnp.asarray(qt.keys), jnp.asarray(qt.values),
                           n=args.sketch_size)
        qa = IX.query_arrays(qsk)
        t0 = time.time()
        s, g, r, m = qfn(*qa, shard, ops)
        jax.block_until_ready(s)
        lat.append((time.time() - t0) * 1000)
        if i == 0:
            print("first query (incl. compile): "
                  f"{lat[0]:.1f} ms; top ids {np.asarray(g)[:5]} r {np.round(np.asarray(r)[:5],3)}")
    lat = np.array(lat[1:]) if len(lat) > 1 else np.array(lat)
    print(f"query latency over {len(lat)} queries: "
          f"mean {lat.mean():.1f} ms  p50 {np.percentile(lat,50):.1f}  "
          f"p90 {np.percentile(lat,90):.1f}  p99 {np.percentile(lat,99):.1f}  "
          f"(paper §5.5: 94% < 100 ms on 1.5k tables)")


if __name__ == "__main__":
    main()
