import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's own workload at production scale: the distributed
top-k join-correlation query program over a sharded sketch index.

Lowers + compiles the shard_map query for a given index size on the
production mesh, and reports the same roofline terms as the LM cells.

    python -m repro.launch.dryrun_engine --cols-per-device 8192 --n 256
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def run(cols_per_device: int, n: int, k: int, multi_pod: bool,
        estimator: str = "pearson", score_chunk: int = 512):
    from repro.engine.index import IndexShard
    from repro.engine import plans as PL
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_cost

    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(mesh.devices.size)
    C = cols_per_device * ndev
    # one compiled plan serves every estimator/scorer (traced request
    # operands, DESIGN.md §6) — `estimator` only names the analysis cell
    shape = PL.ShapePolicy(k_max=k, score_chunk=score_chunk)
    fn = PL.make_scan_fn(mesh, C, n, shape)

    shard_abs = IndexShard(
        key_hash=jax.ShapeDtypeStruct((C, n), jnp.uint32),
        values=jax.ShapeDtypeStruct((C, n), jnp.float32),
        mask=jax.ShapeDtypeStruct((C, n), jnp.float32),
        col_min=jax.ShapeDtypeStruct((C,), jnp.float32),
        col_max=jax.ShapeDtypeStruct((C,), jnp.float32),
        rows=jax.ShapeDtypeStruct((C,), jnp.float32))
    q_abs = (jax.ShapeDtypeStruct((n,), jnp.uint32),
             jax.ShapeDtypeStruct((n,), jnp.float32),
             jax.ShapeDtypeStruct((n,), jnp.float32),
             jax.ShapeDtypeStruct((), jnp.float32),
             jax.ShapeDtypeStruct((), jnp.float32))
    ops_abs = jax.ShapeDtypeStruct((4,), jnp.float32)
    with mesh:
        lowered = fn.lower(*q_abs, shard_abs, ops_abs)
        compiled = lowered.compile()
    rep = hlo_cost.analyze(compiled.as_text())
    # the layout contract (DESIGN.md §10): stage-1/stage-2 stay shard-local;
    # only the [ndev, k] combine strips may cross shards. An accidental
    # all-gather of the [C_local, n] sketch planes dwarfs this bound.
    shard_bytes = cols_per_device * n * 4
    assert rep.collective_bytes < shard_bytes, (
        f"query program moves {rep.collective_bytes:.0f} collective bytes "
        f"per device — more than one [C_local, n] sketch plane "
        f"({shard_bytes}); the scan must not all-gather the index "
        f"({dict(rep.collectives)})")
    ma = compiled.memory_analysis()
    rec = {
        "cell": f"engine_query_C{C}_n{n}", "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": ndev, "columns": C, "sketch_n": n, "score_chunk": score_chunk,
        "memory": {"argument_bytes": int(ma.argument_size_in_bytes),
                   "temp_bytes": int(ma.temp_size_in_bytes)},
        "hlo": {"flops_per_device": rep.flops, "bytes_per_device": rep.bytes,
                "collective_bytes_per_device": rep.collective_bytes,
                "collectives": dict(rep.collectives)},
        "roofline": {
            "compute_s": rep.flops / PEAK_FLOPS,
            "memory_s": rep.bytes / HBM_BW,
            "collective_s": rep.collective_bytes / ICI_BW,
        },
    }
    r = rec["roofline"]
    r["dominant"] = max((r["compute_s"], "compute"), (r["memory_s"], "memory"),
                        (r["collective_s"], "collective"))[1]
    # "useful" work: one O(n²) intersect per candidate (2·n² mul-adds ×3 sums)
    useful = cols_per_device * 2.0 * n * n * 4
    r["useful_ratio"] = useful / max(rep.flops, 1)
    r["bound_s"] = max(r["compute_s"], r["memory_s"], r["collective_s"])
    r["ideal_compute_s"] = useful / PEAK_FLOPS
    r["roofline_fraction"] = r["ideal_compute_s"] / r["bound_s"] if r["bound_s"] else 0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cols-per-device", type=int, default=8192)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--score-chunk", type=int, default=512)
    ap.add_argument("--estimator", default="pearson")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rec = run(args.cols_per_device, args.n, args.k, args.multi_pod,
              estimator=args.estimator, score_chunk=args.score_chunk)
    print(json.dumps(rec, indent=1, default=float))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec, default=float) + "\n")


if __name__ == "__main__":
    main()
