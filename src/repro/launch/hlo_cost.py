"""HLO-text cost walker: FLOPs / bytes / collective bytes with loop scaling.

``compiled.cost_analysis()`` counts each ``while`` body **once**, but our
training steps scan over layers (and microbatches, and sequence chunks), so
naive extraction undercounts by orders of magnitude. This walker parses the
post-optimisation HLO text, finds every while loop's ``known_trip_count``
(recorded by XLA in backend_config), and multiplies body costs through
nested loops.

Cost model (per device — SPMD modules are per-device after partitioning):
  * FLOPs: dots = 2 · numel(out) · Πcontracted ; elementwise/reduce ops =
    numel; descends into fusions for inner dots.
  * bytes: Σ over materialising ops of (operand bytes + output bytes) —
    post-fusion HLO makes fusion boundaries ≈ HBM traffic; bookkeeping ops
    (tuple/gte/parameter/constant/bitcast) are free.
  * collectives: per spec, Σ operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (+ async -start forms),
    scaled by loop trip counts; per-op breakdown retained.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
    "reduce-scatter-start", "all-to-all-start",
}
_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id",
    "get-dimension-size", "iota",
    # -done halves of async pairs (the -start carries the cost)
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "reduce-scatter-done", "all-to-all-done", "async-done",
}
_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sine", "cosine", "logistic",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "atan2", "cbrt", "erf", "remainder",
}


def _shape_bytes_numel(type_str: str) -> Tuple[int, int]:
    """Total (bytes, numel) across every dtype[dims] token in a type string."""
    total_b = 0
    total_n = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total_n += numel
        total_b += numel * _DTYPE_BYTES[dt]
    return total_b, total_n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str           # operands + attrs (unsplit tail of the line)
    operands: List[str]
    is_root: bool = False
    param_index: int = -1  # for parameter ops


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_out_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    unknown_trip_whiles: int = 0

    def merge_scaled(self, other: "CostReport", scale: float):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.collective_bytes += other.collective_bytes * scale
        self.collective_out_bytes += other.collective_out_bytes * scale
        for k, v in other.collectives.items():
            self.collectives[k] += v * scale
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * scale
        self.unknown_trip_whiles += other.unknown_trip_whiles


def parse_computations(hlo_text: str) -> Tuple[Dict[str, List[Op]], Optional[str]]:
    comps: Dict[str, List[Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, tail = m.groups()
        # operands: %refs inside the first balanced paren group
        depth = 1
        i = 0
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = tail[:i]
        rest = tail[i + 1:]
        operands = _OPERAND_RE.findall(operand_str)
        pidx = -1
        if opcode == "parameter":
            try:
                pidx = int(operand_str.strip())
            except ValueError:
                pidx = -1
        comps[cur].append(Op(name=name, type_str=type_str, opcode=opcode,
                             rest=operand_str + "|" + rest, operands=operands,
                             is_root="ROOT" in line.split("=")[0],
                             param_index=pidx))
    return comps, entry


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_b, out_n = _shape_bytes_numel(op.type_str)
    m = _CONTRACT_RE.search(op.rest)
    contract = 1
    if m and op.operands:
        lhs_type = symtab.get(op.operands[0], "")
        mm = _SHAPE_RE.search(lhs_type)
        if mm:
            dims = [int(d) for d in mm.group(2).split(",")] if mm.group(2) else []
            for ci in (int(c) for c in m.group(1).split(",") if c):
                if ci < len(dims):
                    contract *= dims[ci]
    return 2.0 * out_n * contract


def _fusion_flops(comp_name: str, comps, symtabs) -> float:
    total = 0.0
    for op in comps.get(comp_name, ()):  # inner dots only; elementwise numel
        if op.opcode == "dot":
            total += _dot_flops(op, symtabs[comp_name])
        elif op.opcode in _ELEMENTWISE_FLOP_OPS or op.opcode == "reduce":
            _, n = _shape_bytes_numel(op.type_str)
            total += n
        elif op.opcode == "fusion":
            m = _CALLS_RE.search(op.rest)
            if m:
                total += _fusion_flops(m.group(1), comps, symtabs)
    return total


_SLICE_LIKE = {"dynamic-slice", "slice"}


def _fusion_bytes(op: Op, called: str, symtab, comps, symtabs) -> float:
    """HBM traffic of a fusion, honouring fused slice/in-place-update ops.

    A fusion operand consumed *only* through (dynamic-)slice ops inside the
    fused computation is read at slice granularity, not full size (this is
    how scan slices its stacked xs). A fusion rooted at dynamic-update-slice
    writes only the update (XLA performs it in place), and the big aliased
    buffer operand is not re-read.
    """
    ops = comps.get(called, ())
    inner_sym = symtabs.get(called, {})
    params_by_idx = {o.param_index: o.name for o in ops if o.opcode == "parameter"}
    consumers: Dict[str, List[Op]] = defaultdict(list)
    for o in ops:
        for src in o.operands:
            consumers[src].append(o)
    root = next((o for o in ops if o.is_root), None)

    total = 0.0
    for pos, outer_name in enumerate(op.operands):
        full_b, _ = _shape_bytes_numel(symtab.get(outer_name, ""))
        pname = params_by_idx.get(pos)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in _SLICE_LIKE or
                        (c.opcode == "dynamic-update-slice" and c.operands and c.operands[0] == pname)
                        for c in cons):
            sliced = 0.0
            for c in cons:
                if c.opcode in _SLICE_LIKE:
                    sliced += _shape_bytes_numel(c.type_str)[0]
                # DUS buffer operand: in-place, no full read
            total += min(sliced, full_b)
        else:
            total += full_b
    out_b, _ = _shape_bytes_numel(op.type_str)
    if root is not None and root.opcode == "dynamic-update-slice" and len(root.operands) >= 2:
        upd_b, _ = _shape_bytes_numel(inner_sym.get(root.operands[1], ""))
        out_b = min(out_b, upd_b if upd_b else out_b)
    return total + out_b


def analyze(hlo_text: str) -> CostReport:
    comps, entry = parse_computations(hlo_text)
    symtabs = {cn: {op.name: op.type_str for op in ops} for cn, ops in comps.items()}

    def walk(comp_name: str) -> CostReport:
        rep = CostReport()
        symtab = symtabs.get(comp_name, {})
        for op in comps.get(comp_name, ()):
            out_b, out_n = _shape_bytes_numel(op.type_str)
            opb = sum(_shape_bytes_numel(symtab.get(o, ""))[0] for o in op.operands)
            if op.opcode == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                trip = _TRIP_RE.search(op.rest)
                n = int(trip.group(1)) if trip else 1
                if not trip:
                    rep.unknown_trip_whiles += 1
                if body:
                    rep.merge_scaled(walk(body.group(1)), n)
                if cond:
                    rep.merge_scaled(walk(cond.group(1)), n)
                continue
            if op.opcode in ("call", "async-start"):
                m = _CALLS_RE.search(op.rest)
                if m:
                    rep.merge_scaled(walk(m.group(1)), 1.0)
                continue
            if op.opcode == "conditional":
                for branch in re.findall(r"branch_computations=\{([^}]*)\}", op.rest):
                    for b in _OPERAND_RE.findall(branch):
                        rep.merge_scaled(walk(b), 1.0)
                continue
            if op.opcode in COLLECTIVE_OPS:
                key = op.opcode.replace("-start", "")
                rep.collectives[key] += opb
                rep.collective_counts[key] += 1
                rep.collective_bytes += opb
                rep.collective_out_bytes += out_b
                rep.bytes += opb + out_b
                continue
            if op.opcode == "dot":
                rep.flops += _dot_flops(op, symtab)
                rep.bytes += opb + out_b
                continue
            if op.opcode == "fusion":
                m = _CALLS_RE.search(op.rest)
                if m:
                    rep.flops += _fusion_flops(m.group(1), comps, symtabs)
                    rep.bytes += _fusion_bytes(op, m.group(1), symtab, comps, symtabs)
                else:
                    rep.bytes += opb + out_b
                continue
            if op.opcode == "dynamic-slice":
                rep.bytes += 2 * out_b  # read slice + write slice
                continue
            if op.opcode == "dynamic-update-slice":
                upd_b = (_shape_bytes_numel(symtab.get(op.operands[1], ""))[0]
                         if len(op.operands) >= 2 else out_b)
                rep.bytes += 2 * upd_b  # in place: read update, write update
                continue
            if op.opcode in _SKIP_BYTES_OPS:
                continue
            if op.opcode in _ELEMENTWISE_FLOP_OPS or op.opcode == "reduce":
                rep.flops += out_n
            elif op.opcode == "sort":
                rep.flops += out_n * max(math.log2(max(out_n, 2)), 1.0)
            rep.bytes += opb + out_b
        return rep

    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    return walk(entry)
