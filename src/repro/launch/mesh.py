"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh.

    Axes: ``pod`` — the slow inter-pod (DCI) dimension, carrying only
    gradient reduction and FSDP gathers; ``data`` — batch/FSDP; ``model`` —
    tensor/expert parallel.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-compatible ``jax.sharding.AbstractMesh`` constructor.

    jax ≤ 0.4.x takes one tuple of (name, size) pairs; newer releases take
    positional (axis_sizes, axis_names). Device-free either way, so sharding
    rules can be evaluated without real hardware.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))


def make_host_mesh(ndev: int | None = None, name: str = "shard"):
    """Flat mesh over however many (possibly fake) devices exist — used by
    the engine (column-sharded index) and CPU tests."""
    n = ndev or len(jax.devices())
    return jax.make_mesh((n,), (name,))
