"""Lowering/compiling helpers for every cell kind: train / prefill / decode.

These produce the (lowered, compiled) pairs the dry-run and roofline layers
consume. Sharding for the decode caches is resolved per-leaf from logical
axes (ring SWA caches shard their sequence dim over whatever mesh axes the
batch didn't take — see rules.py "cache_seq").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs import shapes as SH
from repro.models import params as MP
from repro.models import transformer as T
from repro.sharding import rules as shr


def _ns(mesh, axes, shape):
    return NamedSharding(mesh, shr.logical_to_pspec(axes, shape, mesh))


def _cache_leaf_axes(field: str, ndim: int):
    table = {
        "k": ("batch", "cache_seq", "kv_heads", None),
        "v": ("batch", "cache_seq", "kv_heads", None),
        "k2": ("batch", "cache_seq", "kv_heads", None),
        "v2": ("batch", "cache_seq", "kv_heads", None),
        "kpos": ("cache_seq",),
        "kpos2": ("cache_seq",),
        "ssm_h": ("batch", "ssm_inner", None),
        "ssm_tail": ("batch", None, "ssm_inner"),
        "rwkv_s": ("batch", "heads", None, None),
        "rwkv_prev_tm": ("batch", None, None),
        "rwkv_prev_cm": ("batch", None, None),
        "xk": ("batch", "cache_seq", "kv_heads", None),
        "xv": ("batch", "cache_seq", "kv_heads", None),
        "enc_out": ("batch", None, None),
        "enc_positions": (None, None),
        "pos": (),
    }
    axes = table.get(field, tuple([None] * ndim))
    return axes[:ndim] if len(axes) >= ndim else tuple([None] * ndim)


def cache_shardings(cache_abs, mesh):
    """NamedShardings for a DecodeCache pytree, resolved per-leaf by name.

    Stacked (uniform-arch) caches carry a leading layers dim → prepend None.
    """
    from repro.models.transformer import LayerCache
    stacked = isinstance(getattr(cache_abs, "layers", None), LayerCache)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    out = []
    for path, leaf in flat:
        field = None
        in_layers = False
        for pp in path:
            nm = getattr(pp, "name", None)
            if nm == "layers":
                in_layers = True
            if nm is not None:
                field = nm
        nd = len(leaf.shape)
        if stacked and in_layers and field not in ("pos",):
            axes = (None,) + _cache_leaf_axes(field or "", nd - 1)
        else:
            axes = _cache_leaf_axes(field or "", nd)
        out.append(_ns(mesh, axes, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, out)


def input_shardings(cfg: ModelConfig, mesh, specs: Dict[str, Any]):
    out = {}
    for k, v in specs.items():
        axes = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = _ns(mesh, axes, v.shape)
    return out


# ----------------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------------

def compile_prefill(cfg: ModelConfig, mesh: Mesh, shape: SH.ShapeSpec):
    specs = SH.input_specs(cfg, shape)
    pdtype = jnp.dtype(cfg.dtype)
    abs_params = MP.abstract_params(cfg, dtype=pdtype)
    p_sh = MP.param_shardings(cfg, mesh)
    in_sh = input_shardings(cfg, mesh, specs)

    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["tokens"],
                         prefix_embeds=batch.get("prefix_embeds"),
                         frames=batch.get("frames"),
                         max_new_tokens=128)

    jt = jax.jit(prefill_step, in_shardings=(p_sh, in_sh))
    shr.set_activation_mesh(mesh)
    try:
        with mesh:
            lowered = jt.lower(abs_params, specs)
            compiled = lowered.compile()
    finally:
        shr.set_activation_mesh(None)
    return lowered, compiled


# ----------------------------------------------------------------------------
# decode (serve_step)
# ----------------------------------------------------------------------------

def compile_serve_step(cfg: ModelConfig, mesh: Mesh, shape: SH.ShapeSpec,
                       donate: bool = True):
    cache_abs, cfg_d = SH.decode_cache_specs(cfg, shape)
    pdtype = jnp.dtype(cfg_d.dtype)
    abs_params = MP.abstract_params(cfg_d, dtype=pdtype)
    p_sh = MP.param_shardings(cfg_d, mesh)
    c_sh = cache_shardings(cache_abs, mesh)
    specs = SH.input_specs(cfg_d, shape)
    tok_sh = input_shardings(cfg_d, mesh, specs)

    def serve_step(params, cache, tokens):
        logits, new_cache = T.decode_step(params, cfg_d, cache, tokens)
        return logits, new_cache

    jt = jax.jit(serve_step,
                 in_shardings=(p_sh, c_sh, tok_sh["tokens"]),
                 out_shardings=(None, c_sh),
                 donate_argnums=(1,) if donate else ())
    shr.set_activation_mesh(mesh)
    try:
        with mesh:
            lowered = jt.lower(abs_params, cache_abs, specs["tokens"])
            compiled = lowered.compile()
    finally:
        shr.set_activation_mesh(None)
    return lowered, compiled


# ----------------------------------------------------------------------------
# train (thin wrapper over train_step.compile_train_step with defaults)
# ----------------------------------------------------------------------------

def default_microbatches(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> int:
    """Pick n_mb so the per-device microbatch is 1 example (memory floor)."""
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh.shape.get(ax, 1)
    per_dev = max(global_batch // dp, 1)
    return per_dev


def compile_train(cfg: ModelConfig, mesh: Mesh, shape: SH.ShapeSpec,
                  microbatches: int | None = None):
    from repro.train import train_step as TS
    specs = SH.input_specs(cfg, shape)
    n_mb = microbatches or default_microbatches(cfg, mesh, shape.global_batch)
    tcfg = TS.TrainConfig(microbatches=n_mb)
    return TS.compile_train_step(cfg, tcfg, mesh, specs)
